"""Traversal profiler: shadow-pass parity, sampling policy, drift detection,
and the measured-d_µ feedback into the §3.6 heuristic dispatch."""

import pathlib
import tempfile
import threading

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import BOTTOM, breadth_first_encode, random_tree, tree_depth
from repro.core.analysis import (
    level_active_fractions,
    mean_traversal_depth,
    observed_depths,
    speculation_waste_ratio,
)
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval import (
    forest_eval_ref,
    profile_forest_eval,
    profile_tree_eval,
    tree_eval_ref,
)
from repro.obs.prof import leaf_drift_distance, survival_from_classes
from repro.serve.engine import BackgroundRetuner, RetunePolicy
from repro.tune import TuneCache, TunedEvaluator
from repro.tune.space import WorkloadShape, backend_tag


def _enc(seed=0, max_depth=6, n_attrs=9, n_classes=5, balance=0.7):
    return breadth_first_encode(random_tree(
        n_attrs=n_attrs, n_classes=n_classes, max_depth=max_depth,
        seed=seed, balance=balance))


def _forest(n_trees=4, **kw):
    return EncodedForest([_enc(seed=s, **kw) for s in range(n_trees)])


def _records(m, a, seed=0, shift=0.0):
    r = np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)
    return r + np.float32(shift)


def _cache():
    return TuneCache(pathlib.Path(tempfile.mkdtemp()) / "c.json")


def _tree_ref(enc, rec):
    return tree_eval_ref(
        jnp.asarray(rec, jnp.float32),
        jnp.asarray(enc.attr_idx, jnp.int32),
        jnp.asarray(enc.threshold, jnp.float32),
        jnp.asarray(enc.child, jnp.int32),
        jnp.asarray(enc.class_val, jnp.int32),
        max_depth=max(tree_depth(enc), 1),
    )


class TestShadowParity:
    """The profiling descent must never disagree with the serving path."""

    def test_tree_classes_bit_exact_with_ref(self):
        for seed in range(4):
            enc = _enc(seed=seed, max_depth=4 + seed)
            rec = _records(128, 9, seed=seed)
            prof = profile_tree_eval(rec, enc)
            want = _tree_ref(enc, rec)
            assert np.array_equal(np.asarray(prof.classes), np.asarray(want))

    def test_exit_depth_matches_host_descent(self):
        enc = _enc(seed=1)
        rec = _records(256, 9, seed=1)
        prof = profile_tree_eval(rec, enc)
        host = observed_depths(enc, rec)
        assert np.array_equal(np.asarray(prof.exit_depth), host)
        assert np.isclose(prof.d_mu(), mean_traversal_depth(host))

    def test_level_active_matches_analysis(self):
        enc = _enc(seed=2)
        rec = _records(200, 9, seed=2)
        depth = max(tree_depth(enc), 1)
        prof = profile_tree_eval(rec, enc)
        want = level_active_fractions(observed_depths(enc, rec), depth)
        np.testing.assert_allclose(np.asarray(prof.level_active), want, atol=1e-6)

    def test_hit_count_accounting(self):
        enc = _enc(seed=3)
        rec = _records(150, 9, seed=3)
        prof = profile_tree_eval(rec, enc)
        # every internal evaluation is one unit of traversal depth
        assert int(np.asarray(prof.node_hits).sum()) == int(
            np.asarray(prof.exit_depth).sum())
        # every record terminates at exactly one leaf
        leaf_hits = np.asarray(prof.leaf_hits)
        assert int(leaf_hits.sum()) == rec.shape[0]
        is_leaf = np.asarray(enc.class_val) != BOTTOM
        assert not leaf_hits[~is_leaf].any()

    def test_extra_rounds_change_nothing(self):
        enc = _enc(seed=4)
        rec = _records(64, 9, seed=4)
        base = profile_tree_eval(rec, enc)
        more = profile_tree_eval(rec, enc, max_depth=tree_depth(enc) + 5)
        assert np.array_equal(np.asarray(base.classes), np.asarray(more.classes))
        assert np.array_equal(np.asarray(base.exit_depth),
                              np.asarray(more.exit_depth))

    def test_forest_classes_bit_exact_with_ref(self):
        forest = _forest(n_trees=5, max_depth=5)
        rec = _records(96, 9, seed=5)
        prof = profile_forest_eval(rec, forest)
        want = forest_eval_ref(
            jnp.asarray(rec, jnp.float32),
            jnp.asarray(forest.attr_idx, jnp.int32),
            jnp.asarray(forest.threshold, jnp.float32),
            jnp.asarray(forest.child, jnp.int32),
            jnp.asarray(forest.class_val, jnp.int32),
            max_depth=max(int(forest.max_depth), 1),
        )
        assert np.array_equal(np.asarray(prof.classes), np.asarray(want))
        assert prof.leaf_histogram().sum() == forest.n_trees * rec.shape[0]


class TestSurvivalAndDrift:
    def test_survival_none_without_an_ensemble(self):
        assert survival_from_classes(np.zeros((64,), np.int32), 4) is None
        assert survival_from_classes(np.zeros((1, 64), np.int32), 4) is None

    def test_unanimous_forest_exits_late_stages(self):
        # T=6, 3 stages: after 2 trees margin 2 <= remaining 4 (alive),
        # after 4 trees margin 4 > remaining 2 (exited) -> mean 0.5
        classes = np.zeros((6, 32), np.int32)
        s = survival_from_classes(classes, 4, stages=3)
        assert s is not None and np.isclose(s, 0.5)

    def test_contested_forest_survives(self):
        # alternating votes keep the margin at <= 1: nothing can exit early
        classes = np.stack([np.full((32,), t % 2, np.int32) for t in range(6)])
        assert np.isclose(survival_from_classes(classes, 4, stages=3), 1.0)

    def test_drift_distance_bounds(self):
        p = np.array([10, 5, 0, 1], float)
        assert leaf_drift_distance(p, p) == 0.0
        assert np.isclose(
            leaf_drift_distance([1, 0, 0], [0, 0, 1]), 1.0)
        # padding: mass moved into a new leaf index counts
        assert leaf_drift_distance([4, 4], [4, 4, 0]) == 0.0
        assert leaf_drift_distance([0, 0], [0, 0]) == 0.0
        assert leaf_drift_distance([1, 1], [0, 0]) == 1.0


class TestTraversalProfiler:
    def _profiler(self, enc, policy, **kw):
        return obs.TraversalProfiler(
            lambda batch: profile_tree_eval(batch, enc),
            policy, n_nodes=int(enc.n_nodes), **kw)

    def test_sampling_cadence_and_metrics(self):
        enc = _enc(seed=0)
        r = obs.Registry()
        p = self._profiler(
            enc, obs.ProfilePolicy(sample_every=4, synchronous=True),
            registry=r)
        rec = _records(64, 9)
        sampled = [p.note_wave("k", rec) for _ in range(9)]
        # first wave always profiles, then every 4th
        assert sampled == [True, False, False, False,
                           True, False, False, False, True]
        snap = obs.snapshot(r)
        assert snap["counters"]["prof.waves"] == 9
        assert snap["counters"]["prof.sampled"] == 3
        assert snap["counters"]["prof.records"] == 3 * 64
        prof = p.profile("k")
        assert prof is not None and prof.samples == 3
        host_d_mu = mean_traversal_depth(observed_depths(enc, rec))
        assert np.isclose(prof.d_mu, host_d_mu)
        assert np.isclose(prof.waste_ratio,
                          speculation_waste_ratio(enc.n_nodes, host_d_mu))
        assert np.isclose(snap["gauges"]['prof.d_mu{bucket="k"}'], host_d_mu)
        assert snap["gauges"]['prof.waste_ratio{bucket="k"}'] > 1.0
        assert snap["histograms"]["prof.exit_depth"]["count"] == 3 * 64
        assert p.keys() == ["k"]

    def test_disabled_policy_profiles_nothing(self):
        enc = _enc(seed=0)
        p = self._profiler(enc, obs.ProfilePolicy(sample_every=0))
        assert p.note_wave("k", _records(32, 9)) is False
        assert p.profile("k") is None and p.d_mu("k") is None

    def test_sample_records_caps_the_pass(self):
        enc = _enc(seed=0)
        p = self._profiler(
            enc,
            obs.ProfilePolicy(sample_every=1, sample_records=50,
                              synchronous=True))
        p.note_wave("k", _records(400, 9))
        assert p.profile("k").records == 50

    def test_profile_errors_are_counted_not_raised(self):
        def boom(batch):
            raise RuntimeError("shadow pass died")

        r = obs.Registry()
        p = obs.TraversalProfiler(
            boom, obs.ProfilePolicy(sample_every=1, synchronous=True),
            registry=r)
        assert p.note_wave("k", _records(8, 4)) is True
        assert obs.snapshot(r)["counters"]["prof.errors"] == 1
        assert p.profile("k") is None

    def test_forest_survival_published(self):
        forest = _forest(n_trees=4, max_depth=4)
        r = obs.Registry()
        p = obs.TraversalProfiler(
            lambda batch: profile_forest_eval(batch, forest),
            obs.ProfilePolicy(sample_every=1, synchronous=True),
            registry=r, n_nodes=int(forest.n_nodes), n_classes=5)
        p.note_wave("fk", _records(64, 9))
        s = p.survival("fk")
        assert s is not None and 0.0 <= s <= 1.0
        assert 'prof.survival{bucket="fk"}' in obs.snapshot(r)["gauges"]

    def test_counter_tracks_land_in_tracer(self):
        enc = _enc(seed=0)
        tr = obs.Tracer()
        p = self._profiler(
            enc, obs.ProfilePolicy(sample_every=1, synchronous=True),
            tracer=tr)
        p.note_wave("k", _records(32, 9))
        tracks = {e.name for e in tr.events() if e.ph == "C"}
        assert tracks == {"prof.d_mu/k", "prof.waste/k"}

    def test_drift_fires_once_then_reanchors(self):
        enc = _enc(seed=6, max_depth=7, balance=0.6)
        events = []
        r = obs.Registry()
        p = self._profiler(
            enc,
            obs.ProfilePolicy(
                sample_every=1, synchronous=True, drift_window=4,
                drift_min_samples=2, drift_threshold=0.05),
            registry=r, on_drift=lambda k, d, rec: events.append((k, d)))
        # steady traffic: window fills, distances stay under the floor
        for i in range(4):
            p.note_wave("k", _records(256, 9, seed=i))
        assert events == []
        # the distribution shifts: records land in different leaves
        shifted = [_records(256, 9, seed=10 + i, shift=5.0) for i in range(4)]
        p.note_wave("k", shifted[0])
        assert len(events) == 1
        key, dist = events[0]
        assert key == "k" and dist > 0.05
        snap = obs.snapshot(r)
        assert snap["counters"]['prof.drift_events{bucket="k"}'] == 1
        assert snap["gauges"]['prof.drift_distance{bucket="k"}'] == dist
        # window re-anchored on the new distribution: sustained shift is quiet
        for s in shifted[1:]:
            p.note_wave("k", s)
        assert len(events) == 1

    def test_async_pass_lands_after_drain(self):
        enc = _enc(seed=0)
        p = self._profiler(enc, obs.ProfilePolicy(sample_every=1))
        assert p.note_wave("k", _records(64, 9)) is True
        p.drain()
        assert p.d_mu("k") is not None


class TestDispatchFeedback:
    """Measured d_µ must reach the §3.6 heuristic with provenance."""

    def _profiled(self, enc, rec):
        p = obs.TraversalProfiler(
            lambda batch: profile_tree_eval(batch, enc),
            obs.ProfilePolicy(sample_every=1, synchronous=True),
            n_nodes=int(enc.n_nodes))
        key = WorkloadShape.of(rec, enc).key(backend_tag())
        assert p.note_wave(key, rec) is True
        return p, key

    def test_measured_d_mu_reaches_heuristic(self):
        enc = _enc(seed=0)
        rec = _records(64, 9)
        prof, key = self._profiled(enc, rec)
        r = obs.Registry()
        ev = TunedEvaluator(enc, cache=_cache(), profiler=prof, registry=r)
        out = ev(rec)
        # the dispatch stays correct while consuming the measurement
        assert np.array_equal(np.asarray(out), np.asarray(_tree_ref(enc, rec)))
        snap = obs.snapshot(r)
        g = 'tune.d_mu{level="tree",source="measured"}'
        assert g in snap["gauges"]
        assert np.isclose(snap["gauges"][g], prof.d_mu(key))
        assert snap["counters"][
            'tune.d_mu_provenance{level="tree",source="measured"}'] == 1
        # the agreement counter answers "did measuring change the pick?"
        agree = [k for k in snap["counters"]
                 if k.startswith('tune.d_mu_agreement{level="tree"')]
        assert sum(snap["counters"][k] for k in agree) == 1

    def test_unprofiled_bucket_falls_back_to_sampled(self):
        enc = _enc(seed=0)
        rec = _records(64, 9)
        r = obs.Registry()
        ev = TunedEvaluator(enc, cache=_cache(), registry=r)
        ev(rec)
        snap = obs.snapshot(r)
        assert snap["counters"][
            'tune.d_mu_provenance{level="tree",source="sampled"}'] == 1
        assert 'tune.d_mu{level="tree",source="measured"}' not in snap["gauges"]

    def test_resolution_is_memoized_per_bucket(self):
        enc = _enc(seed=0)
        rec = _records(64, 9)
        prof, _ = self._profiled(enc, rec)
        r = obs.Registry()
        ev = TunedEvaluator(enc, cache=_cache(), profiler=prof, registry=r)
        ev(rec)
        ev(rec)  # second call: fast path, no second resolution
        snap = obs.snapshot(r)
        assert snap["counters"][
            'tune.d_mu_provenance{level="tree",source="measured"}'] == 1


class TestRetunerForce:
    def test_force_bypasses_gates_and_dedups(self):
        release = threading.Event()
        measured = []

        def measure(batch):
            release.wait(5.0)
            measured.append(batch.shape)
            return object()

        r = obs.Registry()
        rt = BackgroundRetuner(
            measure, lambda key, entry: None,
            RetunePolicy(hot_waves=1000, max_concurrent=1), registry=r)
        batch = _records(16, 4)
        assert rt.force("bucket", batch) is True
        # same bucket while the measurement runs: refused, not queued
        assert rt.force("bucket", batch) is False
        # the single worker slot is taken: other buckets are refused too
        assert rt.force("other", batch) is False
        release.set()
        for t in rt._threads:
            t.join(5.0)
        snap = obs.snapshot(r)
        assert snap["counters"]["serve.retune.forced"] == 1
        assert snap["counters"]["serve.retune.launched"] == 1
        assert measured == [batch.shape]
