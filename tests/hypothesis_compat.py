"""Optional-``hypothesis`` shim for the property tests.

``hypothesis`` is a dev-only dependency; the tier-1 suite must collect and
pass without it.  When the real package is importable we re-export it
untouched.  Otherwise we provide a deterministic stand-in: each strategy can
enumerate a small set of representative fixed examples (bounds, midpoints and
a few seeded interior points) and ``given`` runs the test body over the cross
product sampled down to ``max_examples`` deterministic combinations.

This keeps every ``@given`` property test meaningful (fixed-example
regression sweep) instead of skipped when the dependency is absent.
"""

from __future__ import annotations

import itertools

import numpy as np

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A fixed, deterministic pool of example values."""

        def __init__(self, examples):
            self.examples = list(examples)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            rng = np.random.default_rng(lo * 1000003 + hi)
            interior = [int(rng.integers(lo, hi + 1)) for _ in range(2)]
            vals = sorted({lo, mid, hi, *interior})
            return _Strategy(vals)

        @staticmethod
        def floats(min_value, max_value):
            lo, hi = float(min_value), float(max_value)
            return _Strategy([lo, (lo + hi) / 2.0, hi])

        @staticmethod
        def sampled_from(values):
            return _Strategy(values)

    st = _St()

    def settings(*_a, **_k):  # noqa: D401 - decorator factory, no-op fallback
        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def runner(*outer_args, **outer_kw):
                # Build the combination pool, then deterministically subsample.
                pools = [s.examples for s in arg_strategies]
                pools += [s.examples for s in kw_strategies.values()]
                combos = list(itertools.product(*pools))
                rng = np.random.default_rng(len(combos))
                max_examples = 10
                if len(combos) > max_examples:
                    pick = rng.choice(len(combos), size=max_examples, replace=False)
                    combos = [combos[i] for i in sorted(pick)]
                names = list(kw_strategies)
                n_pos = len(arg_strategies)
                for combo in combos:
                    kw = dict(zip(names, combo[n_pos:]))
                    fn(*outer_args, *combo[:n_pos], **kw)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
