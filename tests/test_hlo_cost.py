"""Trip-count-aware HLO cost analyzer vs XLA's own cost_analysis."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.utils.hlo_cost import analyze, parse_module


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_costs(compiled) -> dict:
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca  # jax 0.4.x wraps in a list


class TestFlops:
    def test_unrolled_matches_xla_exactly(self):
        def f(ws, x):
            for i in range(8):
                x = jnp.tanh(x @ ws[i])
            return x

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((8, 64, 64), jnp.float32),
            jax.ShapeDtypeStruct((16, 64), jnp.float32),
        )
        mine = analyze(c.as_text())
        assert mine.flops == pytest.approx(_xla_costs(c)["flops"], rel=1e-6)

    def test_scan_multiplies_by_trip_count(self):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, ws)[0]

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((12, 64, 64), jnp.float32),
            jax.ShapeDtypeStruct((16, 64), jnp.float32),
        )
        mine = analyze(c.as_text())
        expected = 12 * 2 * 16 * 64 * 64
        assert mine.flops == pytest.approx(expected, rel=1e-6)
        assert mine.unknown_trip_counts == 0

    def test_nested_scans_multiply(self):
        def f(ws, x):
            def inner(c, w):
                return c @ w, None

            def outer(c, _):
                return jax.lax.scan(inner, c, ws)[0], None

            return jax.lax.scan(outer, x, None, length=5)[0]

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((3, 32, 32), jnp.float32),
            jax.ShapeDtypeStruct((8, 32), jnp.float32),
        )
        mine = analyze(c.as_text())
        expected = 5 * 3 * 2 * 8 * 32 * 32
        assert mine.flops == pytest.approx(expected, rel=0.01)

    def test_dot_general_batched_contraction(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
            jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
        )
        mine = analyze(c.as_text())
        assert mine.flops == pytest.approx(2 * 4 * 8 * 16 * 32, rel=1e-6)


class TestBytes:
    def test_unrolled_bytes_close_to_xla(self):
        def f(ws, x):
            for i in range(4):
                x = jnp.tanh(x @ ws[i])
            return x

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((4, 128, 128), jnp.float32),
            jax.ShapeDtypeStruct((32, 128), jnp.float32),
        )
        mine = analyze(c.as_text())
        xla = _xla_costs(c)["bytes accessed"]
        assert mine.bytes == pytest.approx(xla, rel=0.5)

    def test_dus_charges_update_not_buffer(self):
        """KV-cache-style in-place update inside a scan must not charge the
        whole buffer per step."""
        def f(cache, xs):
            def body(c, i):
                c = jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.ones((1, 64), jnp.float32), i, axis=0
                )
                return c, None
            return jax.lax.scan(body, cache, xs)[0]

        c = _compiled(
            f,
            jax.ShapeDtypeStruct((4096, 64), jnp.float32),
            jax.ShapeDtypeStruct((16,), jnp.int32),
        )
        mine = analyze(c.as_text())
        full_buffer = 4096 * 64 * 4
        # per-iteration charge is 2x the 256 B update, NOT the whole buffer;
        # the residual ~4x full is the one-time entry copy (non-donated input),
        # not 16 iterations x 2 x full = 32x
        assert mine.bytes < 5 * full_buffer


class TestCollectives:
    def test_collectives_in_loops_scale_with_trips(self):
        import subprocess, sys, os, textwrap

        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        )
        code = """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.utils.hlo_cost import analyze
        mesh = jax.make_mesh((4,), ('m',))
        def f(ws, x):
            def body(c, w):
                y = c @ w                       # w col-sharded -> gather
                return jax.lax.with_sharding_constraint(y, P()), None
            return jax.lax.scan(body, x, ws)[0]
        with mesh:
            c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, None, 'm')), NamedSharding(mesh, P()),
            )).lower(jax.ShapeDtypeStruct((6, 64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
        hc = analyze(c.as_text())
        total = hc.coll_bytes
        # one weight gather per iteration: 6 x (64*64*4 x ~3/4)
        assert total >= 6 * 64 * 64 * 4 * 0.5, total
        print('OK', total)
        """
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=300, env=env,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestParser:
    def test_parse_module_finds_entry(self):
        def f(x):
            return x * 2 + 1

        c = _compiled(f, jax.ShapeDtypeStruct((8,), jnp.float32))
        comps, entry = parse_module(c.as_text())
        assert entry is not None
        assert entry in comps

    def test_tuple_result_instructions(self):
        def f(x):
            def body(c, _):
                return (c[0] + 1, c[1] * 2), None
            return jax.lax.scan(body, (x, x), None, length=3)[0]

        c = _compiled(f, jax.ShapeDtypeStruct((4,), jnp.float32))
        mine = analyze(c.as_text())   # must not crash on tuple shapes
        assert mine.bytes > 0
