"""Cross-variant conformance suite (ISSUE 10 satellite).

Every registered evaluator — the serial/data-parallel/speculative references,
every tree ``VARIANTS`` entry, every ``FOREST_VARIANTS`` entry (including the
quantized layouts), and the cascade — runs over a shared set of adversarial
fixtures and must be *class-exact* against ``tree_eval_ref`` /
``forest_eval_ref``.  No tolerance anywhere: the paper's encoding is
branchless integer routing, so any numeric drift is a bug, not noise.

Fixture trees: deep, shallow, skewed, degenerate single-leaf, and a tree
where many nodes share one threshold.  Fixture records inject ±inf and NaN
attribute values (NaN compares false on ``v > t`` → routes left) plus rows
that hit thresholds exactly (the ``<=`` / ``>`` tie-break).
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    Node,
    breadth_first_encode,
    eval_data_parallel_tree,
    eval_serial,
    eval_speculative_tree,
    majority_vote,
    random_tree,
    tree_depth,
)
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval import eval_cascade
from repro.kernels.tree_eval.ops import FOREST_VARIANTS, VARIANTS
from repro.kernels.tree_eval.quant import THR_DTYPES, QuantizedForest
from repro.kernels.tree_eval.ref import forest_eval_ref, tree_eval_ref
from repro.kernels.tree_eval.ops import forest_eval_fused_q

N_ATTRS = 7
N_CLASSES = 5
M = 96  # small enough for interpret-mode Pallas, large enough to tile


def _duplicate_threshold_tree() -> Node:
    """Depth-3 full tree where every internal node splits at the same 0.5."""
    def leaf(c):
        return Node(class_val=c)

    def split(attr, left, right):
        return Node(attr=attr, threshold=0.5, left=left, right=right)

    return split(
        0,
        split(1, split(2, leaf(0), leaf(1)), split(3, leaf(2), leaf(3))),
        split(2, split(4, leaf(4), leaf(0)), split(1, leaf(1), leaf(2))),
    )


def _fixture_trees() -> dict[str, Node]:
    return {
        "deep": random_tree(
            n_attrs=N_ATTRS, n_classes=N_CLASSES, max_depth=8, min_depth=6, seed=7
        ),
        "shallow": random_tree(
            n_attrs=N_ATTRS, n_classes=N_CLASSES, max_depth=1, min_depth=1, seed=8
        ),
        "skewed": random_tree(
            n_attrs=N_ATTRS, n_classes=N_CLASSES, max_depth=9, min_depth=2,
            seed=9, balance=0.15,
        ),
        "single_leaf": Node(class_val=3),
        "duplicate_threshold": _duplicate_threshold_tree(),
    }


TREES = {name: breadth_first_encode(root) for name, root in _fixture_trees().items()}
FOREST = EncodedForest(list(TREES.values()))


def _records() -> np.ndarray:
    """(M, A) float32 records with adversarial rows up front."""
    rng = np.random.default_rng(2026)
    rec = rng.normal(size=(M, N_ATTRS)).astype(np.float32)
    # Tie-break rows: attribute exactly equal to the shared 0.5 threshold and
    # to 0.0 (random_tree thresholds are continuous, 0.5 hits the duplicate
    # tree).  v > t must be False on equality → route left, on every path.
    rec[0, :] = 0.5
    rec[1, :] = 0.0
    # ±inf: +inf always routes right past any finite threshold; -inf left.
    rec[2, :] = np.inf
    rec[3, :] = -np.inf
    rec[4, ::2] = np.inf
    rec[4, 1::2] = -np.inf
    # NaN compares false on v > t → must route left like the reference.
    rec[5, :] = np.nan
    rec[6, ::3] = np.nan
    # A mixed row: NaN next to ±inf next to an exact threshold hit.
    rec[7, 0] = np.nan
    rec[7, 1] = np.inf
    rec[7, 2] = -np.inf
    rec[7, 3] = 0.5
    return rec


RECORDS = _records()


def _tree_ref(enc) -> np.ndarray:
    return np.asarray(
        tree_eval_ref(
            jnp.asarray(RECORDS),
            jnp.asarray(enc.attr_idx, jnp.int32),
            jnp.asarray(enc.threshold, jnp.float32),
            jnp.asarray(enc.child, jnp.int32),
            jnp.asarray(enc.class_val, jnp.int32),
            max_depth=max(tree_depth(enc), 1),
        )
    )


TREE_REFS = {name: _tree_ref(enc) for name, enc in TREES.items()}
FOREST_REF = np.asarray(
    forest_eval_ref(
        jnp.asarray(RECORDS),
        jnp.asarray(FOREST.attr_idx, jnp.int32),
        jnp.asarray(FOREST.threshold, jnp.float32),
        jnp.asarray(FOREST.child, jnp.int32),
        jnp.asarray(FOREST.class_val, jnp.int32),
        max_depth=max(int(FOREST.max_depth), 1),
    )
)


def _assert_exact(got, want, label: str) -> None:
    got = np.asarray(got)
    assert got.shape == want.shape, f"{label}: shape {got.shape} != {want.shape}"
    assert got.dtype.kind == "i", f"{label}: non-integer class output {got.dtype}"
    if not np.array_equal(got, want):
        bad = np.argwhere(got != want)
        raise AssertionError(
            f"{label}: {bad.shape[0]} mismatches vs reference, first at "
            f"{bad[0].tolist()}: got {got[tuple(bad[0])]} want {want[tuple(bad[0])]}"
        )


# ---------------------------------------------------------------------------
# Core reference evaluators agree with the serial ground truth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", sorted(TREES))
def test_eval_serial_conforms(fixture):
    enc = TREES[fixture]
    _assert_exact(eval_serial(enc, RECORDS), TREE_REFS[fixture], f"eval_serial/{fixture}")


@pytest.mark.parametrize("fixture", sorted(TREES))
def test_eval_data_parallel_conforms(fixture):
    enc = TREES[fixture]
    got = eval_data_parallel_tree(enc, RECORDS, max_depth=max(tree_depth(enc), 1))
    _assert_exact(got, TREE_REFS[fixture], f"eval_data_parallel/{fixture}")


@pytest.mark.parametrize("fixture", sorted(TREES))
@pytest.mark.parametrize("jumps", [1, 2, 3])
def test_eval_speculative_conforms(fixture, jumps):
    enc = TREES[fixture]
    got = eval_speculative_tree(
        enc, RECORDS, max_depth=max(tree_depth(enc), 1), jumps_per_round=jumps
    )
    _assert_exact(got, TREE_REFS[fixture], f"eval_speculative/{fixture}/j{jumps}")


# ---------------------------------------------------------------------------
# Every registered tree variant, over every fixture
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", sorted(TREES))
@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_tree_variant_conforms(variant, fixture):
    spec = VARIANTS[variant]
    enc = TREES[fixture]
    got = spec.fn(jnp.asarray(RECORDS), enc, max_depth=max(tree_depth(enc), 1))
    _assert_exact(got, TREE_REFS[fixture], f"{variant}/{fixture}")


# ---------------------------------------------------------------------------
# Every registered forest variant (f32 and quantized layouts) on the
# mixed-fixture forest — per-tree outputs class-exact against the reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", sorted(FOREST_VARIANTS))
def test_forest_variant_conforms(variant):
    spec = FOREST_VARIANTS[variant]
    got = spec.fn(
        jnp.asarray(RECORDS), FOREST, max_depth=max(int(FOREST.max_depth), 1)
    )
    _assert_exact(got, FOREST_REF, variant)


@pytest.mark.parametrize("thr_dtype", sorted(THR_DTYPES))
@pytest.mark.parametrize("renumber", [False, True])
def test_quantized_forest_prebuilt_conforms(thr_dtype, renumber):
    """Prebuilt QuantizedForest targets (both dtypes × renumbering) stay exact."""
    qf = QuantizedForest(FOREST, N_ATTRS, thr_dtype=thr_dtype, renumber=renumber)
    for alg in ("speculative", "data_parallel"):
        got = forest_eval_fused_q(jnp.asarray(RECORDS), qf, algorithm=alg)
        _assert_exact(got, FOREST_REF, f"quant/{thr_dtype}/renumber={renumber}/{alg}")


@pytest.mark.parametrize("thr_dtype", sorted(THR_DTYPES))
def test_quantized_forest_split_safe_conforms(thr_dtype):
    """Calibrated (split-safe) rounding must preserve calibration routing.

    NaN/±inf rows stay out of the calibration set (as real feature matrices
    would be cleaned) but are still *evaluated* — split-safe rounding only
    guarantees the calibration set, and finite-threshold routing of ±inf/NaN
    is dtype-independent, so the full fixture batch must stay exact too.
    """
    finite = RECORDS[np.all(np.isfinite(RECORDS), axis=1)]
    qf = QuantizedForest(
        FOREST, N_ATTRS, thr_dtype=thr_dtype, calibration=finite
    )
    got = forest_eval_fused_q(jnp.asarray(RECORDS), qf)
    _assert_exact(got, FOREST_REF, f"quant-split-safe/{thr_dtype}")


# ---------------------------------------------------------------------------
# Cascade at bound=1.0 (no early exit) equals the full majority vote
# ---------------------------------------------------------------------------

def test_cascade_conforms():
    want = np.asarray(majority_vote(jnp.asarray(FOREST_REF), N_CLASSES))
    result = eval_cascade(FOREST, jnp.asarray(RECORDS), n_classes=N_CLASSES, bound=1.0)
    _assert_exact(result.classes, want, "cascade/bound=1.0")
