"""Paper core: Procedures 1–5 — unit + property tests.

Every evaluator (serial P2, data-parallel P3, speculative P4/P5 in both
node-eval formulations) must agree exactly with the branchless serial
reference on every tree geometry hypothesis generates.
"""

import math

import numpy as np
import pytest
# hypothesis is optional: the shim runs a deterministic fixed-example sweep
# when the real package is not installed (see hypothesis_compat.py).
from hypothesis_compat import given, settings, st

from repro.core import (
    BOTTOM,
    breadth_first_encode,
    decode_to_linked,
    eval_data_parallel_tree,
    eval_serial,
    eval_serial_vectorized_host,
    eval_speculative_tree,
    leaf_paths,
    node_depths,
    pad_tree,
    paper_tree,
    perfect_tree,
    pointer_jump,
    processor_node_map,
    random_tree,
    rounds_for_depth,
    tree_depth,
    validate_encoding,
)


def _records(n, a, seed=0):
    return np.random.default_rng(seed).normal(size=(n, a)).astype(np.float32)


# ---------------------------------------------------------------------------
# Procedure 1: encoding
# ---------------------------------------------------------------------------


class TestEncoding:
    def test_paper_tree_geometry(self):
        enc = breadth_first_encode(paper_tree())
        assert enc.n_nodes == 31
        assert enc.n_leaves == 16
        assert enc.n_internal == 15
        assert tree_depth(enc) == 11
        validate_encoding(enc)

    def test_right_child_is_left_plus_one(self):
        enc = breadth_first_encode(perfect_tree(4, 8, 3))
        internal = ~enc.is_leaf_mask
        # by construction child stores left; right = left + 1 must be in range
        assert np.all(enc.child[internal] + 1 < enc.n_nodes)

    def test_leaves_self_loop_with_inf_threshold(self):
        enc = breadth_first_encode(random_tree(n_attrs=5, n_classes=3, max_depth=6, seed=3))
        leaf = enc.is_leaf_mask
        assert np.array_equal(enc.child[leaf], np.nonzero(leaf)[0])
        assert np.all(np.isposinf(enc.threshold[leaf]))

    @given(st.integers(0, 50), st.integers(2, 9), st.floats(0.3, 1.0))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_and_invariants(self, seed, depth, balance):
        root = random_tree(n_attrs=7, n_classes=5, max_depth=depth, seed=seed, balance=balance)
        enc = breadth_first_encode(root)
        validate_encoding(enc)
        back = decode_to_linked(enc)
        assert back.count_nodes() == root.count_nodes()
        assert back.depth() == root.depth()
        enc2 = breadth_first_encode(back)
        for a, b in zip(enc, enc2):
            assert np.array_equal(a, b)

    def test_full_binary_tree_node_count(self):
        enc = breadth_first_encode(perfect_tree(5, 4, 4))
        assert enc.n_nodes == 2**6 - 1
        assert enc.n_leaves == 2**5

    def test_pad_tree_unreachable(self):
        enc = breadth_first_encode(paper_tree())
        padded = pad_tree(enc, 128)
        validate_encoding_ignoring_pad(padded, enc.n_nodes)
        rec = _records(100, 19)
        assert np.array_equal(eval_serial(padded, rec), eval_serial(enc, rec))

    def test_procedure5_tables(self):
        enc = breadth_first_encode(paper_tree())
        lp = leaf_paths(enc)
        pm = processor_node_map(enc)
        assert pm.shape == (15,)
        leaf_idx = np.nonzero(enc.is_leaf_mask)[0]
        assert np.array_equal(lp[leaf_idx], leaf_idx)
        assert np.all(~enc.is_leaf_mask[pm])


def validate_encoding_ignoring_pad(enc, n_real):
    # pad nodes are self-looping leaves with class 0 and no parent
    assert np.all(enc.child[n_real:] == np.arange(n_real, enc.n_nodes))
    assert np.all(enc.class_val[n_real:] == 0)


# ---------------------------------------------------------------------------
# Procedures 2/3/4/5 agree
# ---------------------------------------------------------------------------


EVALUATORS = {
    "data_parallel_fixed": lambda enc, rec, d: eval_data_parallel_tree(enc, rec, max_depth=d),
    "data_parallel_early": lambda enc, rec, d: eval_data_parallel_tree(
        enc, rec, max_depth=d, loop="early_exit"
    ),
    "speculative_j1": lambda enc, rec, d: eval_speculative_tree(
        enc, rec, max_depth=d, jumps_per_round=1
    ),
    "speculative_j2": lambda enc, rec, d: eval_speculative_tree(
        enc, rec, max_depth=d, jumps_per_round=2
    ),
    "speculative_onehot": lambda enc, rec, d: eval_speculative_tree(
        enc, rec, max_depth=d, use_onehot_matmul=True
    ),
    "speculative_early": lambda enc, rec, d: eval_speculative_tree(
        enc, rec, max_depth=d, early_exit=True
    ),
}


@pytest.mark.parametrize("name", sorted(EVALUATORS))
def test_evaluators_match_serial_on_paper_tree(name):
    enc = breadth_first_encode(paper_tree())
    rec = _records(512, 19, seed=1)
    ref = eval_serial(enc, rec)
    out = np.asarray(EVALUATORS[name](enc, rec, tree_depth(enc)))
    assert np.array_equal(out, ref), name


@given(
    seed=st.integers(0, 100),
    depth=st.integers(1, 10),
    balance=st.floats(0.3, 1.0),
    m=st.integers(1, 64),
)
@settings(max_examples=25, deadline=None)
def test_all_evaluators_agree_property(seed, depth, balance, m):
    root = random_tree(n_attrs=6, n_classes=4, max_depth=depth, seed=seed, balance=balance)
    enc = breadth_first_encode(root)
    d = max(tree_depth(enc), 1)
    rec = _records(m, 6, seed=seed + 1)
    ref = eval_serial(enc, rec)
    assert np.array_equal(eval_serial_vectorized_host(enc, rec, d), ref)
    for name, fn in EVALUATORS.items():
        assert np.array_equal(np.asarray(fn(enc, rec, d)), ref), name


def test_boundary_values_follow_left_on_equality():
    """The paper's predicate is strict ``>``: r == t goes LEFT."""
    from repro.core.tree import Node

    root = Node(attr=0, threshold=1.0, left=Node(class_val=0), right=Node(class_val=1))
    enc = breadth_first_encode(root)
    rec = np.array([[1.0], [1.0 + 1e-6], [0.999999], [np.nan]], np.float32)
    ref = eval_serial(enc, rec)
    assert list(ref[:3]) == [0, 1, 0]
    assert ref[3] == 0  # NaN compares false -> left, deterministically
    for name, fn in EVALUATORS.items():
        out = np.asarray(fn(enc, rec, 1))
        assert np.array_equal(out, ref), name


# ---------------------------------------------------------------------------
# Pointer jumping (Procedure 4 reduction)
# ---------------------------------------------------------------------------


class TestPointerJump:
    def test_rounds_for_depth(self):
        assert rounds_for_depth(1) == 1
        assert rounds_for_depth(2) == 1
        assert rounds_for_depth(11, 1) == 4   # ceil(log2 11) = 4
        assert rounds_for_depth(11, 2) == 2
        assert rounds_for_depth(16, 1) == 4
        assert rounds_for_depth(17, 1) == 5

    @given(st.integers(0, 30), st.integers(2, 9))
    @settings(max_examples=20, deadline=None)
    def test_jump_convergence_theta_log_d(self, seed, depth):
        """After ceil(log2 d) doublings the root points at its terminal leaf."""
        import jax.numpy as jnp

        root = random_tree(n_attrs=4, n_classes=3, max_depth=depth, seed=seed)
        enc = breadth_first_encode(root)
        d = max(tree_depth(enc), 1)
        rec = _records(16, 4, seed=seed)
        from repro.core.eval_speculative import speculative_node_eval

        path = speculative_node_eval(
            jnp.asarray(rec), jnp.asarray(enc.attr_idx), jnp.asarray(enc.threshold),
            jnp.asarray(enc.child),
        )
        jumped = pointer_jump(path, rounds_for_depth(d, 1), 1)
        leaf_of_root = np.asarray(jumped[:, 0])
        assert np.all(enc.class_val[leaf_of_root] != BOTTOM)
        assert np.array_equal(
            enc.class_val[leaf_of_root], np.asarray(eval_serial(enc, rec))
        )

    def test_node_depths_consistent(self):
        enc = breadth_first_encode(perfect_tree(4, 4, 4))
        nd = node_depths(enc)
        assert nd[0] == 0
        assert nd.max() == 4
        assert (nd == 4).sum() == 16


# ---------------------------------------------------------------------------
# Windowed evaluation (paper §6 future work, implemented)
# ---------------------------------------------------------------------------


class TestWindowed:
    def test_matches_serial_on_paper_tree(self):
        from repro.core import eval_windowed

        enc = breadth_first_encode(paper_tree())
        rec = _records(256, 19, seed=3)
        ref = eval_serial(enc, rec)
        for w in (1, 2, 4, 16):
            out = np.asarray(eval_windowed(enc, rec, window_levels=w))
            assert np.array_equal(out, ref), w

    @given(st.integers(0, 40), st.integers(2, 10), st.integers(1, 6))
    @settings(max_examples=20, deadline=None)
    def test_windowed_property(self, seed, depth, w):
        from repro.core import eval_windowed

        root = random_tree(n_attrs=5, n_classes=4, max_depth=depth, seed=seed,
                           balance=0.6)
        enc = breadth_first_encode(root)
        rec = _records(32, 5, seed=seed + 1)
        ref = eval_serial(enc, rec)
        out = np.asarray(eval_windowed(enc, rec, window_levels=w))
        assert np.array_equal(out, ref)

    def test_band_width_bounded(self):
        """The per-round node axis is the widest w-level band, not N."""
        from repro.core.windowed import level_offsets

        enc = breadth_first_encode(perfect_tree(8, 4, 4))   # N = 511
        starts = level_offsets(enc)
        w = 3
        widths = [int(starts[min(i + w, len(starts) - 1)] - starts[i])
                  for i in range(0, len(starts) - 1, w)]
        assert max(widths) < enc.n_nodes   # 448 vs 511 for the last band
