"""Early-exit cascade evaluation: exactness, provable exits, dispatch, serving.

The load-bearing property is *exactness under the provable bound*: with
``bound=1.0`` (and with the bound disabled outright) the staged cascade must
return class assignments bit-identical to the tuned full-forest path —
early exit is purely a performance decision.  A record may leave the
cascade only when its accumulated vote margin strictly exceeds the number
of trees it has not yet seen, which makes the exit *unflippable*: no
adversarial completion of the remaining trees can change the argmax.
"""

import json
import pathlib
import tempfile

import numpy as np
import pytest

from repro.core import (
    EncodedForest,
    breadth_first_encode,
    eval_forest_cascade,
    eval_forest_tuned,
    majority_vote,
    random_tree,
)
from repro.kernels.tree_eval import (
    CASCADE_VARIANTS,
    MAJORITY_FAMILY,
    CascadeEvaluator,
    CascadePlan,
    CascadeVariantSpec,
    cascade_eval_ref,
    exit_enabling_prefix,
    forest_votes_fused,
    get_cascade_variant,
    plan_cascade,
    register_cascade_variant,
)
from repro.tune import (
    ForestShape,
    ForestTunedEvaluator,
    TuneCache,
    cascade_search_space,
    cascade_stage_grid,
    measured_survival_rate,
    registry_fingerprint,
    tune_cascade_workload,
)
from repro.tune.cache import CACHE_VERSION

# hypothesis is optional: the shim runs a deterministic fixed-example sweep
# when the real package is not installed (see hypothesis_compat.py).
from hypothesis_compat import given, settings, st


def _forest(n_trees=12, n_attrs=9, n_classes=6, depth_span=5, seed0=0):
    trees = [
        breadth_first_encode(
            random_tree(n_attrs=n_attrs, n_classes=n_classes,
                        max_depth=2 + ((seed0 + i) % depth_span), seed=seed0 + i)
        )
        for i in range(n_trees)
    ]
    return EncodedForest(trees)


def _records(m, a, seed=0):
    # thresholds are normal-distributed, so normal records exercise both sides
    return np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)


def _cache():
    return TuneCache(pathlib.Path(tempfile.mkdtemp()) / "c.json")


def _full_majority(forest, rec, n_classes, cache):
    per_tree = eval_forest_tuned(forest, rec, cache=cache)
    return np.asarray(majority_vote(per_tree, n_classes))


# -- plan geometry -----------------------------------------------------------


def test_exit_enabling_prefix():
    # k trees can decide against T-k outstanding only if margin k > (T-k)·b
    for t in (2, 3, 8, 16, 33):
        for b in (1.0, 0.5, 0.25):
            k = exit_enabling_prefix(t, b)
            assert k > b * (t - k)                    # the prefix can decide
            assert k == 1 or (k - 1) <= b * (t - (k - 1))  # and is minimal


def test_plan_cascade_geometry_and_validation():
    forest = _forest(n_trees=16)
    rec = _records(256, 9, seed=3)
    plan = plan_cascade(forest, rec, n_classes=6, stages=3, bound=1.0)
    assert plan.n_trees == 16 and plan.n_stages == 3
    assert sum(plan.stage_sizes) == 16
    assert sorted(plan.order) == list(range(16))
    # first stage is exit-enabling: its margin can beat all remaining trees
    assert plan.stage_sizes[0] >= exit_enabling_prefix(16, 1.0)
    with pytest.raises(ValueError):
        CascadePlan(order=tuple(range(16)), stage_sizes=(8, 9))   # not a partition
    with pytest.raises(ValueError):
        CascadePlan(order=(0, 0, 1), stage_sizes=(2, 1))          # not a permutation


def test_plan_respects_explicit_order():
    forest = _forest(n_trees=8)
    order = tuple(reversed(range(8)))
    plan = plan_cascade(forest, n_classes=6, stages=2, order=order)
    assert plan.order == order


# -- exactness ---------------------------------------------------------------


def test_cascade_exact_parity_with_tuned_forest():
    forest = _forest(n_trees=12)
    rec = _records(700, 9, seed=1)
    cache = _cache()
    want = _full_majority(forest, rec, 6, cache)
    for bound in (None, 1.0):
        res = eval_forest_cascade(forest, rec, n_classes=6, stages=3, bound=bound)
        assert np.array_equal(np.asarray(res.classes), want), bound
    # provable bound: every exited record's margin beats its remaining trees
    res = eval_forest_cascade(forest, rec, n_classes=6, stages=3, bound=1.0)
    exited = np.asarray(res.exit_stage) >= 0
    remaining = forest.n_trees - np.asarray(res.trees_evaluated)
    assert np.all(np.asarray(res.margin)[exited] > remaining[exited])
    assert np.all(np.asarray(res.trees_evaluated)[~exited] == forest.n_trees)
    assert np.all((np.asarray(res.confidence) >= 0) & (np.asarray(res.confidence) <= 1))


def test_cascade_engines_agree_with_reference():
    forest = _forest(n_trees=10, n_classes=5)
    rec = _records(300, 9, seed=7)
    plan = plan_cascade(forest, rec, n_classes=5, stages=3, bound=1.0)
    ref_cls, ref_stage, ref_trees = cascade_eval_ref(
        rec, forest.attr_idx, forest.threshold, forest.child, forest.class_val,
        max_depth=forest.max_depth, order=plan.order, stage_sizes=plan.stage_sizes,
        n_classes=5, bound=1.0,
    )
    for kw in (
        dict(engine="jnp"),
        dict(engine="pallas", block_m=64, interpret=True),
        dict(engine="jnp", algorithm="data_parallel"),
    ):
        ev = CascadeEvaluator(forest, plan, n_classes=5, bound=1.0, **kw)
        res = ev(rec)
        assert np.array_equal(np.asarray(res.classes), ref_cls), kw
        assert np.array_equal(np.asarray(res.exit_stage), ref_stage), kw
        assert np.array_equal(np.asarray(res.trees_evaluated), ref_trees), kw


def test_forest_votes_fused_matches_onehot_sum():
    forest = _forest(n_trees=9, n_classes=4)
    rec = _records(200, 9, seed=11)
    cache = _cache()
    per_tree = np.asarray(eval_forest_tuned(forest, rec, cache=cache))  # (T, M)
    want = np.zeros((rec.shape[0], 4), np.int64)
    for t in range(forest.n_trees):
        np.add.at(want, (np.arange(rec.shape[0]), per_tree[t]), 1)
    for algorithm, jump_mode in (
        ("speculative", "gather"),
        ("speculative", "onehot"),
        ("data_parallel", "gather"),
    ):
        votes = np.asarray(forest_votes_fused(
            rec, forest, n_classes=4, algorithm=algorithm, jump_mode=jump_mode,
            block_m=64, interpret=True,
        ))
        assert votes.shape == (rec.shape[0], 4)
        assert np.array_equal(votes, want), (algorithm, jump_mode)


# -- property: early exits are provably unflippable --------------------------


@settings(max_examples=10, deadline=None)
@given(
    n_trees=st.integers(4, 20),
    stages=st.integers(2, 4),
    n_classes=st.integers(2, 7),
    seed=st.integers(0, 1000),
)
def test_early_exit_margins_unflippable(n_trees, stages, n_classes, seed):
    forest = _forest(n_trees=n_trees, n_classes=n_classes, seed0=seed % 17)
    rec = _records(120, 9, seed=seed)
    plan = plan_cascade(forest, rec[:64], n_classes=n_classes,
                        stages=stages, bound=1.0)
    res = eval_forest_cascade(forest, rec, n_classes=n_classes,
                              plan=plan, bound=1.0)
    cache = _cache()
    per_tree = np.asarray(eval_forest_tuned(forest, rec, cache=cache))  # (T, M)
    classes = np.asarray(res.classes)
    exit_stage = np.asarray(res.exit_stage)
    trees_eval = np.asarray(res.trees_evaluated)
    order = np.asarray(plan.order)
    for i in np.flatnonzero(exit_stage >= 0):
        k = int(trees_eval[i])
        votes = np.bincount(per_tree[order[:k], i], minlength=n_classes)
        top1 = int(votes.argmax())
        assert top1 == classes[i]
        # adversarial completion: hand every unseen tree to the runner-up —
        # the exit class must still win outright (strict, so argmax
        # tie-breaking toward lower indices can never flip it)
        adv = votes.copy()
        adv[top1] = -1
        runner = int(adv.argmax())
        worst = votes.copy()
        worst[runner] += n_trees - k
        assert votes[top1] > worst[runner]
        # and the full forest agrees with the early answer
        full = np.bincount(per_tree[:, i], minlength=n_classes)
        assert int(full.argmax()) == top1


# -- tuner integration -------------------------------------------------------


def test_cascade_search_space_and_stage_grid():
    shape = ForestShape(t=16, m=1024, n_nodes=128, n_attrs=16,
                        depth_min=3, depth_max=6)
    grid = cascade_stage_grid(shape)
    assert grid and all(s >= 2 for s in grid)
    cands = list(cascade_search_space(shape, 6))
    names = {c.variant for c in cands}
    assert MAJORITY_FAMILY in names
    assert any(n.startswith("forest_cascade_") for n in names)
    for c in cands:
        if c.variant != MAJORITY_FAMILY:
            assert get_cascade_variant(c.variant) is not None
            assert 2 <= dict(c.params)["stages"] <= 4
    # tiny forests cannot stage: no cascade candidates, majority only
    tiny = ForestShape(t=2, m=64, n_nodes=16, n_attrs=8, depth_min=2, depth_max=2)
    assert cascade_stage_grid(tiny) == []
    assert {c.variant for c in cascade_search_space(tiny, 6)} == {MAJORITY_FAMILY}


def test_measured_survival_rate_shape():
    forest = _forest(n_trees=12)
    rec = _records(256, 9, seed=5)
    surv = measured_survival_rate(forest, rec, 6, stages=3)
    assert len(surv) == 3 and surv[0] == 1.0
    assert all(0.0 <= s <= 1.0 for s in surv)
    assert all(b <= a + 1e-9 for a, b in zip(surv, surv[1:]))  # non-increasing


def test_predict_dispatch_parity_and_cache_round_trip():
    forest = _forest(n_trees=12)
    rec = _records(600, 9, seed=9)
    cache = _cache()
    want = _full_majority(forest, rec, 6, cache)

    fev = ForestTunedEvaluator(forest, cache=cache, autotune=True)
    got = np.asarray(fev.predict(rec, 6))
    assert np.array_equal(got, want)
    cand, source = fev.resolve_classes(rec, 6)
    assert source in ("memo", "cache", "autotune")
    assert cand.variant == MAJORITY_FAMILY or cand.variant in CASCADE_VARIANTS

    # the stored winner survives a cold restart through the JSON cache
    fev2 = ForestTunedEvaluator(forest, cache=TuneCache(cache.path), autotune=False)
    got2 = np.asarray(fev2.predict(rec, 6))
    assert np.array_equal(got2, want)
    cand2, source2 = fev2.resolve_classes(rec, 6)
    assert source2 in ("memo", "cache")
    assert cand2.variant == cand.variant


def test_tune_cascade_workload_stores_classes_key():
    forest = _forest(n_trees=12)
    rec = _records(512, 9, seed=13)
    cache = _cache()
    entry, measurements = tune_cascade_workload(
        rec, forest, 6, cache=cache, warmup=1, iters=2)
    assert measurements
    assert entry.variant == MAJORITY_FAMILY or entry.variant in CASCADE_VARIANTS
    raw = json.loads(pathlib.Path(cache.path).read_text())
    assert any("|C6" in k for k in raw["entries"])


def test_cache_version_and_fingerprint_cover_cascade():
    assert CACHE_VERSION >= 3
    base = registry_fingerprint()
    spec = get_cascade_variant(next(iter(CASCADE_VARIANTS)))
    probe = CascadeVariantSpec(
        name="forest_cascade_probe", family=spec.family, algorithm=spec.algorithm,
        engine=spec.engine, jump_mode=spec.jump_mode, tunables=spec.tunables,
        build=spec.build,
    )
    register_cascade_variant(probe)
    registry_fingerprint.cache_clear()   # memoized for the hot dispatch path
    try:
        assert registry_fingerprint() != base
    finally:
        del CASCADE_VARIANTS["forest_cascade_probe"]
        registry_fingerprint.cache_clear()
    assert registry_fingerprint() == base


# -- anytime serving ---------------------------------------------------------


def test_anytime_serving_generous_and_tight_slo():
    from repro.serve import AnytimePolicy, ForestServeEngine, TreeRequest

    forest = _forest(n_trees=12)
    cache = _cache()
    rng = np.random.default_rng(21)
    reqs = [TreeRequest(uid=i, records=rng.normal(size=(96, 9)).astype(np.float32))
            for i in range(4)]
    ref = {r.uid: _full_majority(forest, r.records, 6, cache) for r in reqs}

    eng = ForestServeEngine(forest, max_batch=512, n_classes=6, cache=cache,
                            anytime=AnytimePolicy(slo_ms=10_000.0, stages=3))
    eng.run(reqs)
    assert eng.stats.anytime_waves >= 1
    assert eng.stats.anytime_truncations == 0      # generous SLO: full cascade
    for r in reqs:
        assert r.done and np.array_equal(r.out, ref[r.uid])
        assert r.confidence is not None
        assert np.all((r.confidence >= 0) & (r.confidence <= 1))

    reqs2 = [TreeRequest(uid=i, records=rng.normal(size=(96, 9)).astype(np.float32))
             for i in range(4)]
    eng2 = ForestServeEngine(forest, max_batch=512, n_classes=6, cache=cache,
                             anytime=AnytimePolicy(slo_ms=1e-4, stages=3))
    eng2.run(reqs2)
    # an impossible SLO truncates the cascade after its first stage but
    # still answers every request with a confidence estimate
    assert eng2.stats.anytime_truncations >= 1
    assert eng2.stats.anytime_stages and max(eng2.stats.anytime_stages) < 3
    for r in reqs2:
        assert r.done and r.out is not None and r.confidence is not None

    with pytest.raises(ValueError):
        ForestServeEngine(forest, anytime=AnytimePolicy(slo_ms=1.0))  # no n_classes


# -- streaming overlap stats -------------------------------------------------


def test_stream_overlap_stats_and_first_eval_geometry():
    from repro.dist import ShardedForestEvaluator, StreamingChunker

    forest = _forest(n_trees=8)
    rec = _records(1000, 9, seed=17)
    cache = _cache()
    ev = ShardedForestEvaluator(forest, cache=cache)
    ck = StreamingChunker(ev, chunk_records=256)
    want = np.asarray(eval_forest_tuned(forest, rec, cache=cache))
    out = ck.eval(rec)
    assert np.array_equal(out, want)
    # first eval always honours the configured chunk size, coalescing or not
    assert ck.stats.chunks == 4                    # ceil(1000/256)
    assert len(ck.stats.overlap_ratio) == ck.stats.chunks
    assert all(0.0 <= o <= 1.0 for o in ck.stats.overlap_ratio)
    assert ck.stats.overlap_ratio[0] == 0.0        # nothing to overlap with
    for _ in range(6):                             # let coalescing settle
        assert np.array_equal(ck.eval(rec), want)
    assert ck.stats.coalesced_chunk_records >= ck.chunk_records
