"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only dryrun.py forces 512 host devices."""

import os
import zlib

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def _seed_for(nodeid: str) -> int:
    """Deterministic per-test seed: stable across runs and workers, unique
    per test, overridable for replaying a failure (REPRO_TEST_SEED=N)."""
    env = os.environ.get("REPRO_TEST_SEED")
    if env is not None:
        return int(env)
    return zlib.crc32(nodeid.encode())


@pytest.fixture()
def seeded_rng(request):
    """Per-test np.random.Generator seeded from the test's nodeid.

    The seed is printed so a failing run can be replayed exactly with
    ``REPRO_TEST_SEED=<seed> pytest <nodeid>`` even if the fixture's
    consumers draw data-dependent amounts of randomness.
    """
    seed = _seed_for(request.node.nodeid)
    print(f"[seeded_rng] {request.node.nodeid} seed={seed}")
    return np.random.default_rng(seed)


@pytest.fixture(autouse=True)
def _global_numpy_seed(request):
    """Pin the legacy global NumPy RNG per test so tests that (directly or
    through a library) touch ``np.random.*`` are reproducible and isolated
    from execution order.  The seed is derived from the test's nodeid and
    printed on failure-relevant output (``-s`` / captured on failure)."""
    seed = _seed_for(request.node.nodeid) & 0x7FFFFFFF
    np.random.seed(seed)
    print(f"[np.random seed] {seed}")
    yield
