"""Substrate tests: optimizer, checkpointing, fault-tolerant loop, data
pipeline, serving engine, losses."""

import math
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLM, pipeline_for
from repro.models.api import build_model
from repro.optim.adamw import (
    adamw_apply, adamw_init, clip_by_global_norm, global_norm, lr_at,
)
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import LoopState, SimulatedFailure, StragglerWatchdog, train_loop
from repro.train.step import make_train_step
from repro.utils.losses import chunked_softmax_xent, softmax_xent


class TestAdamW:
    def _quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        return loss, {"w": jnp.zeros(3)}

    def test_converges_on_quadratic(self):
        loss, params = self._quadratic()
        cfg = TrainConfig(lr=0.1, warmup_steps=0, total_steps=300, weight_decay=0.0)
        state = adamw_init(params)
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_apply(params, g, state, cfg)
        assert float(loss(params)) < 1e-3

    def test_grad_clip(self):
        tree = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) > 100
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_lr_schedule_warmup_and_decay(self):
        cfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
        assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
        assert float(lr_at(cfg, jnp.asarray(100))) < 2e-4

    def test_weight_decay_only_on_matrices(self):
        params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
        g = jax.tree.map(jnp.zeros_like, params)
        cfg = TrainConfig(lr=0.1, warmup_steps=0, weight_decay=0.5)
        p2, _, _ = adamw_apply(params, g, adamw_init(params), cfg)
        assert float(jnp.abs(p2["w"] - 1.0).max()) > 1e-3       # decayed
        assert float(jnp.abs(p2["scale"] - 1.0).max()) < 1e-6   # untouched


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 4))}}
        ckpt.save(str(tmp_path), 7, tree)
        assert ckpt.latest_step(str(tmp_path)) == 7
        out, manifest = ckpt.restore(str(tmp_path), 7, tree)
        assert manifest["step"] == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_atomic_publish_no_partial_dirs(self, tmp_path):
        tree = {"a": jnp.ones(5)}
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, tree)
        names = sorted(os.listdir(tmp_path))
        assert "step_000001" in names and "step_000002" in names
        assert not any(n.endswith(".tmp") for n in names)

    def test_prune_keeps_newest(self, tmp_path):
        tree = {"a": jnp.ones(2)}
        for s in range(5):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.prune(str(tmp_path), keep=2)
        steps = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
        assert sorted(steps) == ["step_000003", "step_000004"]

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 0, {"a": jnp.ones((4,))})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 0, {"a": jnp.ones((5,))})

    def test_async_saver(self, tmp_path):
        saver = ckpt.AsyncSaver()
        saver.submit(str(tmp_path), 3, {"a": jnp.ones(4)})
        saver.wait()
        assert ckpt.latest_step(str(tmp_path)) == 3


class TestFaultTolerantLoop:
    def _setup(self, tmp_path):
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                          dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=12, ckpt_every=3,
                           ckpt_dir=str(tmp_path))
        step = jax.jit(make_train_step(model, tcfg))
        pipe = pipeline_for(cfg, ShapeConfig("s", 16, 2, "train"))
        batches = lambda i: jax.tree.map(jnp.asarray, pipe(i))
        state = LoopState(params=params, opt_state=adamw_init(params), step=0)
        return state, step, batches, tcfg

    def test_loop_runs_and_checkpoints(self, tmp_path):
        state, step, batches, tcfg = self._setup(tmp_path)
        state, report = train_loop(state, step, batches, tcfg, max_steps=7)
        assert report.final_step == 7
        assert ckpt.latest_step(str(tmp_path)) == 6
        assert report.restarts == 0

    def test_restart_after_injected_failure(self, tmp_path):
        state, step, batches, tcfg = self._setup(tmp_path)
        fired = {"n": 0}

        def injector(i):
            if i == 5 and fired["n"] == 0:
                fired["n"] += 1
                raise SimulatedFailure("node died")

        def restore_fn(last_step):
            tree = {"params": state.params, "opt": state.opt_state}
            loaded, _ = ckpt.restore(tcfg.ckpt_dir, last_step, tree)
            return LoopState(params=loaded["params"], opt_state=loaded["opt"],
                             step=last_step)

        final, report = train_loop(
            state, step, batches, tcfg, max_steps=8,
            failure_injector=injector, restore_fn=restore_fn,
        )
        assert report.restarts == 1
        assert report.final_step == 8          # replayed through the failure

    def test_deterministic_replay(self, tmp_path):
        """Same (seed, step) → same batch → restart reproduces the loss."""
        state, step, batches, tcfg = self._setup(tmp_path)
        _, r1 = train_loop(state, step, batches, tcfg, max_steps=4)
        state2, _, _, _ = self._setup(tmp_path)
        _, r2 = train_loop(state2, step, batches, tcfg, max_steps=4)
        np.testing.assert_allclose(r1.losses, r2.losses, rtol=1e-6)

    def test_straggler_watchdog(self):
        w = StragglerWatchdog(factor=3.0, warmup=3)
        for _ in range(5):
            assert not w.observe(0.1)
        assert w.observe(1.0)
        assert w.events == 1


class TestDataPipeline:
    def test_deterministic_by_step(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        p = SyntheticLM(cfg)
        a, b = p(5), p(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = p(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=2))
        b = p(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert np.all(b["labels"][:, -1] == -1)

    def test_sharding_is_slice_of_global(self):
        p = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=8))
        full = p(2)
        shard = p.shard(2, rank=1, world=4)
        np.testing.assert_array_equal(shard["tokens"], full["tokens"][2:4])

    def test_family_pipelines(self):
        from repro.configs.registry import get_smoke_config

        vlm = get_smoke_config("qwen2-vl-72b")
        b = pipeline_for(vlm, ShapeConfig("s", 8, 2, "train"))(0)
        assert "embeds" in b and "positions" in b and "tokens" not in b
        assert b["positions"].shape == (2, 3, 8)
        aud = get_smoke_config("whisper-medium")
        b = pipeline_for(aud, ShapeConfig("s", 8, 2, "train"))(0)
        assert b["embeds"].shape == (2, aud.encoder.n_frames, aud.d_model)


class TestServeEngine:
    def test_wave_batched_generation(self):
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, params, max_batch=2, max_len=48)
        rng = np.random.default_rng(0)
        reqs = [
            Request(uid=i, prompt=rng.integers(0, 64, size=8).astype(np.int32),
                    max_new_tokens=5)
            for i in range(3)
        ]
        done = eng.run(reqs, pad_to=8)
        assert all(r.done for r in done)
        assert all(len(r.out_tokens) == 5 for r in done)
        assert eng.stats.waves == 2            # 2 + 1 across waves

    def test_greedy_matches_stepwise_forward(self):
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        prompt = np.arange(6, dtype=np.int32) % 64
        eng = ServeEngine(model, params, max_batch=1, max_len=32)
        req = Request(uid=0, prompt=prompt, max_new_tokens=4)
        eng.run([req])
        # reference: argmax rollout with full forwards
        toks = list(prompt)
        out_ref = []
        for _ in range(4):
            lg, _ = model.forward(params, {"tokens": jnp.asarray([toks])})
            nxt = int(jnp.argmax(lg[0, -1]))
            out_ref.append(nxt)
            toks.append(nxt)
        assert req.out_tokens == out_ref


class TestLosses:
    def test_softmax_xent_masks_padded_vocab(self):
        logits = jnp.zeros((2, 4, 16)).at[..., 12:].set(100.0)  # pad region hot
        labels = jnp.zeros((2, 4), jnp.int32)
        nll, _ = softmax_xent(logits, labels, vocab_size=12)
        assert abs(float(nll) - math.log(12)) < 1e-4

    def test_chunked_equals_dense(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 30, size=(2, 16)), jnp.int32)
        dense, _ = softmax_xent(x @ w, labels, vocab_size=30)
        for chunk in (4, 8, 16):
            c, _ = chunked_softmax_xent(x, w, labels, vocab_size=30, chunk=chunk)
            np.testing.assert_allclose(float(c), float(dense), rtol=1e-5)

    def test_chunked_gradients_match(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(2, 8, 8)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 16, size=(2, 8)), jnp.int32)
        g_dense = jax.grad(lambda w_: softmax_xent(x @ w_, labels, vocab_size=16)[0])(w)
        g_chunk = jax.grad(
            lambda w_: chunked_softmax_xent(x, w_, labels, vocab_size=16, chunk=4)[0]
        )(w)
        # f32 summation order differs between the chunked and dense paths;
        # rtol leaves room for one ulp-scale accumulation difference
        np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_dense), rtol=5e-4)

    def test_label_masking(self):
        logits = jnp.asarray(np.random.default_rng(2).normal(size=(1, 4, 8)), jnp.float32)
        labels = jnp.asarray([[1, 2, -1, -1]], jnp.int32)
        nll_masked, nv = softmax_xent(logits, labels, vocab_size=8)
        assert float(nv) == 2.0
