"""Property-based tests for the quantized forest layouts (ISSUE 10 satellite).

Two properties pin the split-safe rounding contract:

1. **Round-trip**: quantizing thresholds against a calibration set and
   evaluating that same calibration set must reproduce the f32 routing
   exactly — for every generated tree geometry, dtype, and calibration draw.
2. **Tie-break**: records sitting *exactly on* a threshold take the left
   branch (``v > t`` strict) on the f32 path, and must keep doing so on the
   quantized path — the routing interval's ``v_lo <= t' < v_hi`` rule makes
   equality land left on both.
"""

from __future__ import annotations

import numpy as np
import pytest
# hypothesis is optional: the shim runs a deterministic fixed-example sweep
# when the real package is not installed (see hypothesis_compat.py).
from hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import breadth_first_encode, random_tree, tree_depth
from repro.core.forest import EncodedForest
from repro.kernels.tree_eval.ops import forest_eval_fused_q
from repro.kernels.tree_eval.quant import (
    THR_DTYPES,
    QuantizedForest,
    quantize_thresholds,
    routing_interval,
)
from repro.kernels.tree_eval.ref import forest_eval_ref

N_ATTRS = 5
N_CLASSES = 4


def _forest(seed: int, depth: int) -> EncodedForest:
    trees = [
        breadth_first_encode(
            random_tree(
                n_attrs=N_ATTRS, n_classes=N_CLASSES, max_depth=depth,
                min_depth=min(depth, 2), seed=seed + i,
            )
        )
        for i in range(3)
    ]
    return EncodedForest(trees)


def _ref(forest: EncodedForest, rec) -> np.ndarray:
    return np.asarray(
        forest_eval_ref(
            jnp.asarray(rec, jnp.float32),
            jnp.asarray(forest.attr_idx, jnp.int32),
            jnp.asarray(forest.threshold, jnp.float32),
            jnp.asarray(forest.child, jnp.int32),
            jnp.asarray(forest.class_val, jnp.int32),
            max_depth=max(int(forest.max_depth), 1),
        )
    )


# ---------------------------------------------------------------------------
# Property 1: split-safe round-trip preserves calibration routing
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    depth=st.integers(min_value=1, max_value=7),
    thr_dtype=st.sampled_from(sorted(THR_DTYPES)),
    scale=st.floats(min_value=0.05, max_value=50.0),
)
def test_split_safe_roundtrip_preserves_calibration_routing(
    seed, depth, thr_dtype, scale
):
    forest = _forest(seed, depth)
    rng = np.random.default_rng(seed)
    # Scale stresses different bf16/f16 exponent ranges; include exact
    # threshold hits so the calibration set exercises the tie-break interval.
    cal = (rng.normal(size=(64, N_ATTRS)) * scale).astype(np.float32)
    thr = np.unique(forest.threshold[np.isfinite(forest.threshold)])
    if thr.size:
        cal[: min(8, thr.size), 0] = thr[: min(8, thr.size)].astype(np.float32)
    qf = QuantizedForest(forest, N_ATTRS, thr_dtype=thr_dtype, calibration=cal)
    got = np.asarray(forest_eval_fused_q(jnp.asarray(cal), qf))
    want = _ref(forest, cal)
    assert np.array_equal(got, want), (
        f"split-safe {thr_dtype} changed routing of its own calibration set "
        f"(seed={seed}, depth={depth}, scale={scale})"
    )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    thr_dtype=st.sampled_from(sorted(THR_DTYPES)),
)
def test_quantized_interval_membership(seed, thr_dtype):
    """Every quantized threshold lies inside its node's routing interval."""
    enc = breadth_first_encode(
        random_tree(n_attrs=N_ATTRS, n_classes=N_CLASSES, max_depth=5, seed=seed)
    )
    rng = np.random.default_rng(seed)
    cal = rng.normal(size=(128, N_ATTRS)).astype(np.float32)
    attr_values = {
        a: np.sort(cal[:, a].astype(np.float64)) for a in range(N_ATTRS)
    }
    leaf = np.asarray(enc.is_leaf_mask, bool)
    q, safe = quantize_thresholds(
        np.asarray(enc.threshold, np.float32),
        leaf,
        np.asarray(enc.attr_idx, np.int32),
        thr_dtype=thr_dtype,
        attr_values=attr_values,
    )
    for n in range(enc.n_nodes):
        if leaf[n]:
            assert safe[n], "leaves (+inf self-loops) are always safe"
            continue
        t = float(enc.threshold[n])
        tq = float(np.float32(q[n]))
        v_lo, v_hi = routing_interval(attr_values[int(enc.attr_idx[n])], t)
        if safe[n]:
            assert v_lo <= tq < v_hi, (
                f"node {n}: quantized threshold {tq} outside routing interval "
                f"[{v_lo}, {v_hi}) of t={t}"
            )
        else:
            # Unsafe means *no* narrow candidate fits the interval — the
            # nearest cast certainly must not (otherwise it would be safe).
            assert not (v_lo <= tq < v_hi), (
                f"node {n}: cast {tq} fits [{v_lo}, {v_hi}) yet marked unsafe"
            )


# ---------------------------------------------------------------------------
# Property 2: exact-hit records keep the strict `<=`/`>` tie-break
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    thr_dtype=st.sampled_from(sorted(THR_DTYPES)),
)
def test_tie_break_on_quantized_path(seed, thr_dtype):
    forest = _forest(seed, 5)
    # Build records that hit every threshold exactly: for each finite
    # threshold t, a row with all attributes = t.  v > t is False on
    # equality → strict left routing, on the f32 AND the quantized path.
    thr = np.unique(forest.threshold[np.isfinite(forest.threshold)]).astype(
        np.float32
    )[:32]
    rec = np.repeat(thr[:, None], N_ATTRS, axis=1)
    want = _ref(forest, rec)

    # Universal mode (no calibration): must be bit-exact for any input.
    qf = QuantizedForest(forest, N_ATTRS, thr_dtype=thr_dtype)
    got = np.asarray(forest_eval_fused_q(jnp.asarray(rec), qf))
    assert np.array_equal(got, want), "universal quantization broke a tie-break"

    # Split-safe mode calibrated on the tie rows themselves: the routing
    # interval has v_lo == t, so t' >= t keeps equality routing left.
    qs = QuantizedForest(forest, N_ATTRS, thr_dtype=thr_dtype, calibration=rec)
    got_s = np.asarray(forest_eval_fused_q(jnp.asarray(rec), qs))
    assert np.array_equal(got_s, want), "split-safe quantization broke a tie-break"


@pytest.mark.parametrize("thr_dtype", sorted(THR_DTYPES))
def test_tie_break_both_directions_single_split(thr_dtype):
    """One split, records straddling + hitting it: left iff ``v <= t``."""
    from repro.core import Node

    t = 0.7281349  # not exactly representable in bf16 or f16
    root = Node(
        attr=0, threshold=t,
        left=Node(class_val=0), right=Node(class_val=1),
    )
    forest = EncodedForest([breadth_first_encode(root)])
    eps = float(np.finfo(np.float32).eps) * abs(t)
    rec = np.zeros((3, N_ATTRS), np.float32)
    rec[0, 0] = np.float32(t) - np.float32(eps)   # below → left
    rec[1, 0] = np.float32(t)                     # exact hit → left (strict >)
    rec[2, 0] = np.nextafter(np.float32(t), np.float32(np.inf))  # above → right
    want = _ref(forest, rec)
    assert want.tolist() == [[0, 0, 1]]
    qs = QuantizedForest(forest, N_ATTRS, thr_dtype=thr_dtype, calibration=rec)
    got = np.asarray(forest_eval_fused_q(jnp.asarray(rec), qs))
    assert np.array_equal(got, want), (
        f"{thr_dtype}: tie-break rows routed {got.tolist()} vs f32 {want.tolist()}"
    )
