"""repro.obs: registry semantics, thread-safety regressions, exporters, and
the serve-stack integration contract — a traced `ForestServeEngine` wave must
yield nested serve.wave > stream.eval > kernel.dispatch spans and a snapshot
carrying per-bucket wave-latency percentiles, per-stage cascade survival and
the chunker's overlap-ratio histogram.
"""

import json
import pathlib
import re
import sys
import tempfile
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core import EncodedForest, breadth_first_encode, random_tree
from repro.tune import TuneCache


def _forest(n_trees=8, n_attrs=9, n_classes=6, seed0=0):
    trees = [
        breadth_first_encode(
            random_tree(n_attrs=n_attrs, n_classes=n_classes,
                        max_depth=2 + (i % 4), seed=seed0 + i)
        )
        for i in range(n_trees)
    ]
    return EncodedForest(trees)


def _cache():
    return TuneCache(pathlib.Path(tempfile.mkdtemp()) / "c.json")


def _records(m, a, seed=0):
    return np.random.default_rng(seed).normal(size=(m, a)).astype(np.float32)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_gauge_histogram_basics(self):
        r = obs.Registry()
        c = r.counter("t.count", "a counter")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

        g = r.gauge("t.gauge")
        g.set(3.5)
        assert g.value == 3.5

        h = r.histogram("t.hist", boundaries=(1.0, 10.0, 100.0))
        for v in (0.5, 5.0, 50.0, 500.0):
            h.observe(v)
        s = h.state()
        assert s["count"] == 4 and s["bucket_counts"] == [1, 1, 1, 1]
        assert s["min"] == 0.5 and s["max"] == 500.0
        p = h.percentiles()
        assert p["p50"] is not None and p["p50"] <= p["p95"] <= p["p99"]

    def test_labels_memoise_children(self):
        r = obs.Registry()
        c = r.counter("t.labelled", "", ("k",))
        assert c.labels(k="a") is c.labels(k="a")
        c.labels(k="a").inc(2)
        c.labels(k="b").inc()
        got = {lv: s.value for lv, s in c.series()}
        assert got == {("a",): 2, ("b",): 1}

    def test_observe_many_matches_repeated_observe(self):
        bs = (1.0, 4.0, 16.0)
        vals = [0.1, 1.0, 2.0, 4.5, 16.0, 99.0, 0.0]
        r = obs.Registry()
        one, many = (r.histogram(n, boundaries=bs) for n in ("t.one", "t.many"))
        for v in vals:
            one.observe(v)
        many.observe_many(vals)
        assert one.state() == many.state()
        # and the pure-python fallback agrees with the numpy path
        nonp = r.histogram("t.nonp", boundaries=bs)
        import repro.obs.metrics as metrics_mod

        saved = metrics_mod._np
        metrics_mod._np = None
        try:
            nonp.observe_many(vals)
        finally:
            metrics_mod._np = saved
        assert nonp.state() == many.state()

    def test_observe_many_empty_is_noop(self):
        r = obs.Registry()
        h = r.histogram("t.empty")
        h.observe_many([])
        h.observe_many(np.array([]))
        assert h.state()["count"] == 0

    def test_disabled_registry_mutations_are_noops(self):
        r = obs.Registry(enabled=False)
        c, g, h = r.counter("t.c"), r.gauge("t.g"), r.histogram("t.h")
        c.inc(10)
        g.set(7)
        h.observe(1.0)
        h.observe_many([1.0, 2.0])
        assert c.value == 0 and g.value == 0 and h.state()["count"] == 0

    def test_duplicate_registration(self):
        r = obs.Registry()
        c = r.counter("t.dup", "help", ("k",))
        # identical re-registration hands back the same instrument
        assert r.counter("t.dup", "help", ("k",)) is c
        with pytest.raises(obs.DuplicateMetricError):
            r.gauge("t.dup")                       # kind conflict
        with pytest.raises(obs.DuplicateMetricError):
            r.counter("t.dup", "help", ("other",))  # label conflict

    def test_counter_inc_is_thread_safe(self):
        """Regression for the serve-path retunes race: `stats.retunes += 1`
        from the BackgroundRetuner worker could lose increments against the
        request thread.  The locked counter must count exactly."""
        r = obs.Registry()
        c = r.counter("t.race")
        n_threads, per_thread = 4, 20_000
        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-5)    # force frequent preemption
        try:
            ts = [threading.Thread(target=lambda: [c.inc() for _ in range(per_thread)])
                  for _ in range(n_threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
        assert c.value == n_threads * per_thread

    def test_engine_retunes_counter_exact_under_contention(self):
        """The engine-facing regression: concurrent m_retunes.inc() from a
        worker thread and reads of the compat `.retunes` property never lose
        an increment."""
        from repro.serve.engine import ForestEngineStats

        stats = ForestEngineStats(obs.Registry())
        per_thread = 10_000
        seen = []

        def bump():
            for _ in range(per_thread):
                stats.m_retunes.inc()

        def read():
            for _ in range(per_thread):
                seen.append(stats.retunes)

        ts = [threading.Thread(target=bump), threading.Thread(target=bump),
              threading.Thread(target=read)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert stats.retunes == 2 * per_thread
        assert all(0 <= v <= 2 * per_thread for v in seen)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


class TestExport:
    def _populated(self):
        r = obs.Registry()
        r.counter("x.count", "c", ("k",)).labels(k="a").inc(3)
        r.gauge("x.gauge").set(1.5)
        h = r.histogram("x.hist", "h", boundaries=(1.0, 10.0))
        h.observe_many([0.5, 5.0, 50.0])
        return r

    def test_snapshot_round_trips_json(self):
        snap = obs.snapshot(self._populated())
        again = json.loads(json.dumps(snap))
        assert again["counters"]['x.count{k="a"}'] == 3
        assert again["gauges"]["x.gauge"] == 1.5
        hist = again["histograms"]["x.hist"]
        assert hist["count"] == 3 and hist["bucket_counts"] == [1, 1, 1]
        assert hist["p50"] is not None

    def test_empty_histogram_percentiles_are_null(self):
        r = obs.Registry()
        r.histogram("x.none")
        hist = obs.snapshot(r)["histograms"]["x.none"]
        assert hist["count"] == 0
        assert hist["p50"] is None and hist["p99"] is None

    def test_prometheus_text_shape(self):
        text = obs.prometheus_text(self._populated())
        assert text.endswith("\n")
        lines = text.splitlines()
        assert "# TYPE x_count counter" in lines
        assert 'x_count{k="a"} 3' in lines
        assert "# TYPE x_hist histogram" in lines
        # histogram triplet: cumulative buckets + +Inf + sum/count
        assert 'x_hist_bucket{le="1"} 1' in lines
        assert 'x_hist_bucket{le="+Inf"} 3' in lines
        assert "x_hist_count 3" in lines
        for line in lines:
            if not line.startswith("#"):
                float(line.rsplit(" ", 1)[1])    # every sample value parses

    # one sample line: name{label="value",...} value — label values quoted,
    # pairs joined by a bare comma, backslash/quote/newline escaped
    _SAMPLE_RE = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
        r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\["\\n])*"'
        r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\["\\n])*")*\})?'
        r' \S+$')

    def test_prometheus_line_format_with_hostile_label_values(self):
        # bucket keys carry |, :, = already; make sure the exposition also
        # survives quotes, backslashes, newlines and spaces in label values
        r = obs.Registry()
        hostile = 'cpu:cpu:x1|M64 "quoted" back\\slash\nnewline'
        r.counter("x.esc", "c", ("bucket", "mode")).labels(
            bucket=hostile, mode="a b").inc(2)
        h = r.histogram("x.lhist", "h", ("k",), boundaries=(1.0,))
        h.labels(k='q"v').observe(0.5)
        text = obs.prometheus_text(r)
        for line in text.splitlines():
            if line.startswith("#"):
                continue
            assert self._SAMPLE_RE.match(line), f"unparseable line: {line!r}"
        counter_line = next(l for l in text.splitlines()
                            if l.startswith("x_esc{"))
        assert '",mode=' in counter_line          # no whitespace separator
        assert '\\"quoted\\"' in counter_line     # escaped quotes
        assert "back\\\\slash" in counter_line    # escaped backslash
        assert "\\nnewline" in counter_line       # newline never splits a line
        assert counter_line.endswith(" 2")
        # _merge splices le= into existing labels with a bare comma too
        assert 'x_lhist_bucket{k="q\\"v",le="1"} 1' in text.splitlines()
        assert 'x_lhist_bucket{k="q\\"v",le="+Inf"} 1' in text.splitlines()
        # the snapshot keeps the dotted name with the same escaping
        snap = obs.snapshot(r)
        (key,) = snap["counters"]
        assert key.startswith('x.esc{bucket="') and '\\"quoted\\"' in key


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_and_chrome_export(self, tmp_path):
        tr = obs.Tracer()
        with tr.span("outer", a=1):
            with tr.span("inner"):
                pass
        tr.instant("marker", b=2)
        names = [e.name for e in tr.events()]
        assert names == ["inner", "outer", "marker"]   # recorded on exit
        path = tmp_path / "trace.json"
        tr.write_chrome_trace(path)
        doc = json.loads(path.read_text())
        evs = {e["name"]: e for e in doc["traceEvents"]}
        outer, inner = evs["outer"], evs["inner"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        # instants export as zero-duration complete events
        assert evs["marker"]["ph"] == "X" and evs["marker"]["dur"] == 0

    def test_set_after_exit_lands_in_event(self):
        tr = obs.Tracer()
        with tr.span("late") as sp:
            pass
        sp.set(result=42)
        (ev,) = tr.events()
        assert ev.args["result"] == 42

    def test_disabled_tracer_records_nothing(self):
        tr = obs.Tracer(enabled=False)
        with tr.span("x") as sp:
            sp.set(k=1)
        tr.instant("y")
        assert tr.events() == []
        assert obs.NULL_TRACER.events() == []

    def test_ring_buffer_keeps_newest(self):
        tr = obs.Tracer(capacity=4)
        for i in range(10):
            tr.instant(f"e{i}")
        names = [e.name for e in tr.events()]
        assert names == ["e6", "e7", "e8", "e9"]
        assert tr.dropped == 6
        tr.clear()
        assert tr.events() == [] and tr.dropped == 0

    def test_counter_samples_export_as_counter_tracks(self):
        tr = obs.Tracer()
        tr.counter("prof.d_mu/k", 3.5, series="d_mu")
        tr.counter("prof.d_mu/k", 4.25, series="d_mu")
        with tr.span("x"):
            pass
        doc = tr.chrome_trace()
        json.dumps(doc)
        cs = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
        # stepped timeline: successive numeric samples, point values only
        assert [e["args"]["d_mu"] for e in cs] == [3.5, 4.25]
        assert all("dur" not in e for e in cs)
        assert all(e["name"] == "prof.d_mu/k" and e["cat"] == "prof" for e in cs)
        (x,) = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert "dur" in x
        # disabled tracer: counters are no-ops like spans
        assert obs.NULL_TRACER.counter("c", 1.0) is None
        assert obs.NULL_TRACER.events() == []

    def test_ring_overflow_under_concurrent_writers(self):
        """The serve path traces from the request thread while profiler and
        retuner workers trace from theirs; eviction must lose only the oldest
        spans and the dropped counter must account for every one of them."""
        cap = 256
        tr = obs.Tracer(capacity=cap)
        n_threads, per_thread = 4, 1500

        def work(tid):
            for i in range(per_thread):
                with tr.span(f"t{tid}", cat="test", idx=i):
                    pass

        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-5)    # force frequent preemption
        try:
            ts = [threading.Thread(target=work, args=(t,))
                  for t in range(n_threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        finally:
            sys.setswitchinterval(old)
        evs = tr.events()
        total = n_threads * per_thread
        assert len(evs) == cap
        assert tr.dropped == total - cap    # nothing lost unaccounted
        by_writer: dict[str, list] = {}
        for e in evs:
            by_writer.setdefault(e.name, []).append(e.args["idx"])
        assert by_writer, "ring empty after concurrent writes"
        for idxs in by_writer.values():
            # survivors are exactly a suffix of that writer's stream:
            # eviction is oldest-first and appends preserve per-thread
            # order, so a surviving span implies every later one survived
            assert idxs == list(range(idxs[0], per_thread))


# ---------------------------------------------------------------------------
# streaming chunker edge cases
# ---------------------------------------------------------------------------


class _FakeEvaluator:
    """records → (T, m) without blocking, like ShardedForestEvaluator."""

    class forest:
        n_trees = 4

    def __call__(self, rec):
        return jnp.zeros((4, rec.shape[0]), jnp.int32)


class TestStreamOverlapEdges:
    def test_zero_record_eval(self):
        from repro.dist import StreamingChunker

        ck = StreamingChunker(_FakeEvaluator(), chunk_records=64)
        out = ck.eval(np.zeros((0, 9), np.float32))
        assert out.shape == (4, 0)
        assert ck.stats.chunks == 0
        assert ck.stats.overlap_ratio == [] and ck.stats.chunk_ms == []
        assert obs.snapshot(ck.stats.registry)["histograms"][
            "stream.overlap_ratio"]["count"] == 0

    def test_single_chunk_has_zero_overlap(self):
        from repro.dist import StreamingChunker

        ck = StreamingChunker(_FakeEvaluator(), chunk_records=1024)
        ck.eval(_records(100, 9))
        assert ck.stats.chunks == 1
        assert ck.stats.overlap_ratio == [0.0]

    def test_inflight_one_still_bounds_overlap(self):
        from repro.dist import StreamingChunker

        ck = StreamingChunker(_FakeEvaluator(), chunk_records=64, inflight=1,
                              auto_coalesce=False)
        ck.eval(_records(400, 9))
        assert ck.stats.chunks == 7                     # ceil(400/64)
        rs = ck.stats.overlap_ratio
        assert len(rs) == 7 and rs[0] == 0.0
        assert all(0.0 <= o <= 1.0 for o in rs)
        # histogram twin saw the same observations
        hist = obs.snapshot(ck.stats.registry)["histograms"]["stream.overlap_ratio"]
        assert hist["count"] == 7


# ---------------------------------------------------------------------------
# anytime accounting when the SLO is never exceeded
# ---------------------------------------------------------------------------


class TestAnytimeAccounting:
    def test_generous_slo_never_truncates(self):
        from repro.serve import AnytimePolicy, ForestServeEngine, TreeRequest

        registry = obs.Registry()
        forest = _forest()
        eng = ForestServeEngine(
            forest, max_batch=256, n_classes=6, cache=_cache(),
            anytime=AnytimePolicy(slo_ms=60_000.0, stages=3),
            registry=registry,
        )
        reqs = [TreeRequest(uid=i, records=_records(64, 9, seed=i))
                for i in range(3)]
        eng.run(reqs)
        n_waves = eng.stats.anytime_waves
        assert n_waves >= 1
        assert eng.stats.anytime_truncations == 0
        # no deadline pressure: the only early stop is every record exiting,
        # so each wave accounts 1..stages stages and none count as truncated
        assert len(eng.stats.anytime_stages) == n_waves
        assert all(1 <= s <= 3 for s in eng.stats.anytime_stages)
        snap = obs.snapshot(registry)
        assert snap["counters"].get("serve.anytime.truncations", 0) == 0
        stages = snap["histograms"]["serve.anytime.stages_run"]
        assert stages["count"] == n_waves
        assert stages["sum"] == sum(eng.stats.anytime_stages)
        conf = snap["histograms"]["serve.anytime.confidence"]
        assert conf["count"] == sum(len(r.records) for r in reqs)
        assert 0.0 <= conf["min"] and conf["max"] <= 1.0


# ---------------------------------------------------------------------------
# serve-stack integration: one registry + tracer across the whole stack
# ---------------------------------------------------------------------------


def _contains(outer: dict, inner: dict) -> bool:
    return (outer["tid"] == inner["tid"]
            and outer["ts"] <= inner["ts"]
            and inner["ts"] + inner.get("dur", 0)
            <= outer["ts"] + outer.get("dur", 0))


class TestServeStackIntegration:
    def test_traced_wave_spans_and_snapshot(self, tmp_path):
        from repro.serve import AnytimePolicy, ForestServeEngine, TreeRequest

        registry, tracer = obs.Registry(), obs.Tracer()
        forest = _forest()
        cache = _cache()

        stream_eng = ForestServeEngine(
            forest, max_batch=256, chunk_records=64, n_classes=6, cache=cache,
            registry=registry, tracer=tracer,
        )
        stream_eng.run([TreeRequest(uid=i, records=_records(128, 9, seed=i))
                        for i in range(2)])
        anytime_eng = ForestServeEngine(
            forest, max_batch=256, n_classes=6, cache=cache,
            anytime=AnytimePolicy(slo_ms=60_000.0, stages=3),
            registry=registry, tracer=tracer,
        )
        anytime_eng.run([TreeRequest(uid=9, records=_records(64, 9, seed=9))])

        # -- spans: the Chrome trace nests wave > chunked eval > kernel ----
        doc = tracer.chrome_trace()
        json.dumps(doc)                                # serialisable
        by_name: dict[str, list] = {}
        for ev in doc["traceEvents"]:
            by_name.setdefault(ev["name"], []).append(ev)
        for name in ("serve.wave", "stream.eval", "stream.chunk.submit",
                     "kernel.dispatch", "serve.vote", "cascade.eval"):
            assert name in by_name, f"span {name!r} missing from trace"
        assert any(
            _contains(w, e) and _contains(e, k)
            for w in by_name["serve.wave"]
            for e in by_name["stream.eval"]
            for k in by_name["kernel.dispatch"]
        ), "no serve.wave > stream.eval > kernel.dispatch nesting"
        assert any(
            _contains(w, c)
            for w in by_name["serve.wave"]
            for c in by_name["cascade.eval"]
        ), "anytime wave does not contain its cascade.eval span"

        # -- snapshot: per-bucket latency percentiles, cascade survival, ---
        # -- chunker overlap -----------------------------------------------
        snap = obs.snapshot(registry)
        waves = {k: v for k, v in snap["histograms"].items()
                 if k.startswith('serve.wave_ms{engine="forest"')}
        assert waves, "no per-bucket serve.wave_ms series"
        for hist in waves.values():
            assert hist["count"] >= 1
            assert hist["p50"] is not None
            assert hist["p50"] <= hist["p95"] <= hist["p99"]
        survival = {k: v for k, v in snap["histograms"].items()
                    if k.startswith("cascade.stage_survival{")}
        assert 1 <= len(survival) <= 3                  # one series per stage run
        for hist in survival.values():
            assert hist["count"] >= 1 and 0.0 <= hist["max"] <= 1.0
        overlap = snap["histograms"]["stream.overlap_ratio"]
        assert overlap["count"] == stream_eng.stats.chunks > 0
        assert snap["counters"]['serve.waves{engine="forest"}'] >= 2

        # -- exporters stay consistent with the live registry --------------
        text = obs.prometheus_text(registry)
        assert "serve_wave_ms_bucket" in text
        assert "cascade_stage_survival_bucket" in text
        out = tmp_path / "snap.json"
        obs.write_json_snapshot(registry, out)
        assert json.loads(out.read_text()) == json.loads(json.dumps(snap))
